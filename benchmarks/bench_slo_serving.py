"""Goodput under p95-SLO vs offered load: FIFO baseline vs SLO scheduler.

Every other serving bench is CLOSED-LOOP (submit everything, drain): queue
delay is an artifact of the drain order and SLO misses cannot happen.
This bench replays an OPEN-LOOP bursty Poisson trace (``repro.workload``)
against the same engine under two schedulers:

* ``fifo`` — head-of-queue admission with monolithic admission prefill
  (the server's historical behavior);
* ``slo`` — priority + earliest-deadline-first admission, chunked prefill
  under a per-tick token budget, preemption of lower-priority streams
  (docs/slo_scheduling.md).

The workload mixes two classes: INTERACTIVE (short prompts, short
outputs, high priority, a tight per-request deadline) and BATCH (long
prompts, long outputs, low priority, no deadline).  Under FIFO a burst of
batch requests parks the interactive tail behind monolithic prefills and
slot hogging; the SLO scheduler preempts and interleaves, so interactive
deadlines hold while batch absorbs the queueing.

Everything is measured in deterministic scheduler TICKS (arrivals are
mapped onto the tick grid, latency is completion_tick - submit_tick), so
the two gated claims are noise-free and enforced in every mode including
``--smoke``:

* ``claim_slo_goodput_beats_fifo`` — goodput (new tokens of requests that
  met their deadline, per tick of total drain) is strictly higher under
  the SLO scheduler at the same offered load;
* ``claim_chunked_prefill_bounds_stall`` — the largest single-tick
  admission prefill under the SLO scheduler stays below one full-prompt
  prefill (FIFO's per-admission stall) AND within the configured chunk
  budget (budget + one schedule window of slack, since a fresh stream
  always makes at least one window of progress).
"""
from __future__ import annotations

import os
import sys
from collections import defaultdict
from typing import Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_serving_batch import _tiny_pair


def _trace(cfg: dict):
    """Bursty two-class open-loop trace (tick_s = 1.0: arrival times ARE
    tick indices)."""
    from repro.workload import LengthDist, WorkloadClass, synthesize
    classes = [
        WorkloadClass(
            name="interactive", priority=1, slo_ticks=cfg["slo_ticks"],
            prompt_len=LengthDist("uniform", (6, 10)),
            output_len=LengthDist("fixed", (cfg["interactive_new"],)),
            weight=cfg["interactive_weight"]),
        WorkloadClass(
            name="batch", priority=0, slo_ticks=None,
            prompt_len=LengthDist("uniform", (cfg["batch_prompt_lo"],
                                              cfg["batch_prompt_hi"])),
            output_len=LengthDist("fixed", (cfg["batch_new"],)),
            weight=1.0 - cfg["interactive_weight"]),
    ]
    return synthesize(classes, rate=cfg["rate"], n=cfg["n_requests"],
                      seed=cfg["seed"], bursty=True,
                      burst_factor=cfg["burst_factor"])


def _drive(server, trace) -> dict:
    """Open-loop replay: requests become visible at their arrival tick
    whether or not the server kept up, then the server drains."""
    by_tick = defaultdict(list)
    for tr in trace:
        by_tick[int(tr.arrival_s)].append(tr)
    last_arrival = max(by_tick) if by_tick else 0
    t = 0
    while (t <= last_arrival or server.queue or server._slot_rid):
        for tr in by_tick.get(t, []):
            server.submit(tr.prompt, tr.max_new_tokens,
                          priority=tr.priority, slo_ticks=tr.slo_ticks)
        server.step()
        t += 1
        assert t < 100_000, "open-loop drive failed to drain"
    stats = server.throughput_stats()
    resp = server.responses
    good = sum(r.result.new_tokens for r in resp if r.slo_met)
    slo_resp = [r for r in resp if r.slo_ticks is not None]
    stats["ticks_total"] = t
    stats["goodput_tokens_per_tick"] = good / max(t, 1)
    stats["slo_met_frac"] = (sum(r.slo_met for r in slo_resp)
                             / max(len(slo_resp), 1))
    stats["p95_queue_delay_ticks"] = float(__import__("numpy").percentile(
        [r.queue_delay_ticks for r in resp], 95))
    stats["p95_latency_ticks"] = float(__import__("numpy").percentile(
        [r.latency_ticks for r in resp], 95))
    return stats


def _serve(pair, trace, cfg: dict, scheduler) -> dict:
    from repro.core import EngineSpec, make_controller
    from repro.serving.engine import SpecServer
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=cfg["gamma_max"],
                           seed=cfg["seed"])
    srv = SpecServer(*pair, ctrl, spec=EngineSpec(
        backend="paged", batch_size=cfg["batch_size"],
        max_len=cfg["max_len"], block_size=cfg["block_size"],
        pool_tokens=cfg["pool_tokens"], prefix_cache=True,
        prefill_chunk=cfg["prefill_chunk"], seed=cfg["seed"]),
        scheduler=scheduler)
    return _drive(srv, trace)


def run(quick: bool = False, smoke: bool = False) -> dict:
    from benchmarks.common import record_serving_bench, save_json
    from repro.serving.scheduler import SLOScheduler

    if smoke or quick:
        cfg = dict(n_requests=12, rate=0.5, burst_factor=8.0, seed=7,
                   interactive_weight=0.5, interactive_new=6, slo_ticks=10,
                   batch_prompt_lo=28, batch_prompt_hi=40, batch_new=24,
                   batch_size=2, max_len=192, block_size=8,
                   pool_tokens=1024, prefill_chunk=8, gamma_max=4,
                   prefill_budget=12)
    else:
        cfg = dict(n_requests=32, rate=0.6, burst_factor=8.0, seed=7,
                   interactive_weight=0.5, interactive_new=8, slo_ticks=12,
                   batch_prompt_lo=40, batch_prompt_hi=64, batch_new=32,
                   batch_size=4, max_len=256, block_size=8,
                   pool_tokens=2048, prefill_chunk=8, gamma_max=4,
                   prefill_budget=32)

    pair = _tiny_pair(n_layers_t=2, d_model_t=64, n_layers_d=1, d_model_d=32)
    trace = _trace(cfg)
    n_int = sum(1 for t in trace if t.priority == 1)
    print(f"  trace: {len(trace)} requests ({n_int} interactive), "
          f"last arrival tick {int(max(t.arrival_s for t in trace))}",
          file=sys.stderr)

    fifo = _serve(pair, trace, cfg, scheduler=None)
    slo = _serve(pair, trace, cfg, scheduler=SLOScheduler(
        max_prefill_tokens_per_tick=cfg["prefill_budget"]))
    for name, st in (("fifo", fifo), ("slo", slo)):
        print(f"  {name}: goodput={st['goodput_tokens_per_tick']:.2f} "
              f"tok/tick over {st['ticks_total']} ticks  "
              f"slo_met={st['slo_met_frac']:.2f}  "
              f"p95_queue_delay={st['p95_queue_delay_ticks']:.0f} ticks  "
              f"preempt={st['preemption_events']}  "
              f"max_prefill/tick={st['max_prefill_tokens_per_tick']}",
              file=sys.stderr)

    # one full-prompt prefill = the largest non-cached prompt suffix a
    # monolithic admission pays in a single tick
    full_prefill = max(len(t.prompt) - 1 for t in trace)
    claim_goodput = bool(slo["goodput_tokens_per_tick"]
                         > fifo["goodput_tokens_per_tick"])
    claim_stall = bool(
        slo["max_prefill_tokens_per_tick"] < full_prefill
        and slo["max_prefill_tokens_per_tick"]
        <= cfg["prefill_budget"] + cfg["prefill_chunk"] - 1)

    summary = {
        "config": cfg,
        "n_requests": len(trace),
        "workload": {"classes": ["interactive", "batch"],
                     "bursty": True, "rate_per_tick": cfg["rate"],
                     "burst_factor": cfg["burst_factor"]},
        "ticks_total": {"fifo": fifo["ticks_total"],
                        "slo": slo["ticks_total"]},
        "goodput_tokens_per_tick": {
            "fifo": fifo["goodput_tokens_per_tick"],
            "slo": slo["goodput_tokens_per_tick"]},
        "slo_met_frac": {"fifo": fifo["slo_met_frac"],
                         "slo": slo["slo_met_frac"]},
        "p95_queue_delay_ticks": {
            "fifo": fifo["p95_queue_delay_ticks"],
            "slo": slo["p95_queue_delay_ticks"]},
        "p95_latency_s": {"fifo": fifo["p95_latency_s"],
                          "slo": slo["p95_latency_s"]},
        "per_priority": {"fifo": fifo["per_priority"],
                         "slo": slo["per_priority"]},
        "preemption_events": {"fifo": fifo["preemption_events"],
                              "slo": slo["preemption_events"]},
        "max_prefill_tokens_per_tick": {
            "fifo": fifo["max_prefill_tokens_per_tick"],
            "slo": slo["max_prefill_tokens_per_tick"]},
        "full_prompt_prefill_tokens": full_prefill,
        "claim_slo_goodput_beats_fifo": claim_goodput,
        "claim_chunked_prefill_bounds_stall": claim_stall,
        "engine": {"fifo": fifo["engine"], "slo": slo["engine"]},
    }
    suffix = "_smoke" if smoke else ""
    save_json(f"slo_serving{suffix}",
              {"summary": summary, "fifo": fifo, "slo": slo})
    record_serving_bench(f"slo_serving{suffix}", summary)
    return summary


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI config")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    summary = run(quick=args.quick, smoke=args.smoke)
    ok_good = summary["claim_slo_goodput_beats_fifo"]
    ok_stall = summary["claim_chunked_prefill_bounds_stall"]
    print(f"claim_slo_goodput_beats_fifo={ok_good}")
    print(f"claim_chunked_prefill_bounds_stall={ok_stall}")
    # both claims are tick-denominated and deterministic for a fixed
    # seed/config, so they gate EVERY mode, --smoke included
    sys.exit(0 if (ok_good and ok_stall) else 1)
