"""Appendix A.2: one-threshold-per-heuristic pool vs a multi-threshold pool
(3 thresholds per heuristic at 0.5x/1x/1.5x the calibrated value).
Paper: the small pool is ~12% better overall."""
from __future__ import annotations

from repro.core import StaticGamma, TapOutSequence
from repro.core.arms import (ADAEDL_DEFAULTS, Arm, _adaedl, _logit_margin,
                             _max_confidence, _svip, _svip_difference)

from .common import (GAMMA_MAX, calibrated_pool, calibrated_thresholds,
                     evaluate_method, get_corpus, save_json, trained_pair)

_MAKERS = {"max_confidence": _max_confidence, "svip": _svip,
           "svip_difference": _svip_difference, "logit_margin": _logit_margin}


def _multi_pool(th):
    pool = [Arm("adaedl", _adaedl(ADAEDL_DEFAULTS["g_coef"]))]
    for name, maker in _MAKERS.items():
        for mult in (0.5, 1.0, 1.5):
            h = round(float(th[name]) * mult, 4)
            pool.append(Arm(f"{name}_{mult}", maker(h), h))
    return pool


def run(quick: bool = False) -> dict:
    draft, target = trained_pair("llama-1b-8b")
    corpus = get_corpus()
    prompts = [ids[:48] for _, ids in
               corpus.prompts("specbench", 13 if quick else 26, seed=37)]
    base = evaluate_method(draft, target, StaticGamma(6), prompts,
                           max_new=40 if quick else 64)
    th = calibrated_thresholds("llama-1b-8b")
    res = {}
    for name, pool in (("default_pool", calibrated_pool("llama-1b-8b")),
                       ("multi_threshold_pool", _multi_pool(th))):
        ctrl = TapOutSequence(GAMMA_MAX, "ucb1", "blend", pool=pool)
        r = evaluate_method(draft, target, ctrl, prompts,
                            max_new=40 if quick else 64)
        res[name] = {"speedup": base.cost_per_token / max(r.cost_per_token, 1e-12),
                     "m": r.m, "accept_rate": r.accept_rate,
                     "n_arms": len(pool)}
    out = {"table": res,
           "claim_small_pool_wins":
               bool(res["default_pool"]["speedup"] >=
                    res["multi_threshold_pool"]["speedup"])}
    save_json("a2_more_arms", out)
    return out
