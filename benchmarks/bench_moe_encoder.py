"""MoE and encoder-conditioned serving workloads (docs/workloads.md).

Two measured-and-GATED claims, one per workload axis:

* ``claim_encoder_segment_bytes_1_over_n`` — N concurrent streams decoding
  against ONE shared encoder input back their cross-attention K/V with a
  single refcounted segment: the encoder-segment pool's unique bytes are
  exactly ``1/N`` of the logical (per-stream) bytes.  Counted from
  ``EncoderSegmentPool.stats()`` — deterministic, gates every mode
  including ``--smoke``.
* ``claim_moe_routed_cost_bandit_visible`` — a MoE-target session surfaces
  its routed-expert activation density into the engine's modeled session
  cost: ``describe()["moe"]`` carries ``routed_frac > 0`` and a measured
  ``mean_routing_density >= 1`` (a gamma-token verify hits more distinct
  experts than one decode token), and feeding those into
  ``modeled_session_cost`` yields a routed verify cost at or above the
  density-blind figure — the workload-dependent trade-off the TapOut
  meta-bandit's cost-adjusted reward learns from.  Deterministic, gates
  every mode.

Appends a ``moe_encoder`` summary row to BENCH_serving.json (the committed
perf trajectory; ``scripts/check_bench_schema.py`` requires the row to
stamp routed-expert AND shared-segment stats) and writes
``artifacts/bench/moe_encoder[_smoke].json``.
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

_ARCH = {"moe": "qwen3-moe-235b-a22b", "encdec": "seamless-m4t-large-v2"}


def _pair(kind):
    """Smoke-sized registry target + a plain dense draft sharing its vocab
    (greedy verification keeps the unconditioned draft exact)."""
    import jax
    from repro.configs.registry import smoke_config
    from repro.core import ModelBundle
    from repro.models import ModelConfig
    from repro.models import transformer as T
    tcfg = smoke_config(_ARCH[kind])
    dcfg = ModelConfig(name="drf", arch_type="dense", num_layers=2,
                       d_model=64, num_heads=2, num_kv_heads=1, d_ff=128,
                       vocab_size=tcfg.vocab_size)
    return (ModelBundle(T.init_params(dcfg, jax.random.PRNGKey(1)), dcfg),
            ModelBundle(T.init_params(tcfg, jax.random.PRNGKey(0)), tcfg))


def _mk_engine(draft, target, batch_size, seed=0):
    from repro.core.controller import make_controller
    from repro.core.engine import PagedSpecEngine
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=4, seed=seed)
    return PagedSpecEngine(draft, target, ctrl, batch_size=batch_size,
                           max_len=128, block_size=16, seed=seed)


def _drain(eng, n_streams, max_new, max_ticks=400):
    t0 = time.perf_counter()
    new_tokens = 0
    for _ in range(max_ticks):
        live = [s for s in range(n_streams)
                if eng.slots[s] is not None
                and not eng.slots[s]["done"]
                and eng.slots[s]["res"].new_tokens < max_new]
        if not live:
            break
        eng.session_step_batch()
    for s in range(n_streams):
        if eng.slots[s] is not None:
            new_tokens += eng.slots[s]["res"].new_tokens
            eng.close_stream(s)
    wall = time.perf_counter() - t0
    return {"new_tokens": new_tokens, "wall_s": wall,
            "tokens_per_s": new_tokens / max(wall, 1e-9)}


def run(quick: bool = False, smoke: bool = False) -> dict:
    import numpy as np

    from benchmarks.common import record_serving_bench, save_json
    from repro.core.rewards import modeled_session_cost

    n_streams = 4
    max_new = 6 if smoke else (12 if quick else 24)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 500, size=int(n)).tolist()
               for n in rng.integers(5, 12, size=n_streams)]

    # ---- encoder axis: N streams, ONE encoding -> one shared segment.
    draft, enc_t = _pair("encdec")
    fe = rng.standard_normal((enc_t.cfg.encdec.frontend_len,
                              enc_t.cfg.encdec.frontend_dim)).astype(
                                  np.float32)
    enc_eng = _mk_engine(draft, enc_t, n_streams)
    for s, p in enumerate(prompts):
        enc_eng.open_stream(s, list(p), frame_embeds=fe)
    seg = enc_eng.enc_pool.stats()
    ratio = seg["unique_bytes"] / max(seg["logical_bytes"], 1)
    claim_enc = bool(seg["logical_refs"] == n_streams
                     and ratio <= 1.0 / n_streams + 1e-9)
    enc_tp = _drain(enc_eng, n_streams, max_new)
    enc_blob = enc_eng.describe()
    encoder_stats = {"streams": n_streams,
                     "unique_bytes": seg["unique_bytes"],
                     "logical_bytes": seg["logical_bytes"],
                     "segment_bytes_ratio": ratio,
                     "hits": seg["hits"], "misses": seg["misses"]}
    print(f"  encoder segments: {seg['unique_bytes']} unique vs "
          f"{seg['logical_bytes']} logical bytes over {n_streams} streams "
          f"(ratio {ratio:.3f}, target <= {1.0 / n_streams:.3f})",
          file=sys.stderr)

    # ---- MoE axis: routed-expert density flows into the modeled cost.
    draft_m, moe_t = _pair("moe")
    moe_eng = _mk_engine(draft_m, moe_t, 2)
    for s, p in enumerate(prompts[:2]):
        moe_eng.open_stream(s, list(p))
    moe_tp = _drain(moe_eng, 2, max_new)
    moe_blob = moe_eng.describe()
    moe = moe_blob.get("moe", {})
    rf = float(moe.get("routed_frac", 0.0))
    dens = float(moe.get("mean_routing_density", 0.0))
    cost_routed = modeled_session_cost(4, draft_m.cost_per_token,
                                       moe_t.cost_per_token,
                                       routed_frac=rf, routing_density=dens)
    cost_flat = modeled_session_cost(4, draft_m.cost_per_token,
                                     moe_t.cost_per_token)
    claim_moe = bool(rf > 0.0 and dens >= 1.0 and moe.get("sessions", 0) > 0
                     and cost_routed >= cost_flat)
    moe_stats = {"routed_frac": rf, "mean_routing_density": dens,
                 "sessions": int(moe.get("sessions", 0)),
                 "modeled_session_cost_routed": cost_routed,
                 "modeled_session_cost_flat": cost_flat}
    print(f"  moe: routed_frac={rf:.3f} density={dens:.3f} over "
          f"{moe.get('sessions', 0)} sessions — modeled verify cost "
          f"{cost_routed:.1f} vs density-blind {cost_flat:.1f}",
          file=sys.stderr)

    payload = {
        "config": {"n_streams": n_streams, "max_new": max_new,
                   "encdec_arch": _ARCH["encdec"], "moe_arch": _ARCH["moe"]},
        "encoder": encoder_stats,
        "moe": moe_stats,
        "throughput": {"encdec": enc_tp, "moe": moe_tp},
        "claim_encoder_segment_bytes_1_over_n": claim_enc,
        "claim_moe_routed_cost_bandit_visible": claim_moe,
    }
    suffix = "_smoke" if smoke else ""
    save_json(f"moe_encoder{suffix}", payload)
    record_serving_bench(f"moe_encoder{suffix}", {
        "engine": {"moe": moe_blob, "encdec": enc_blob},
        "encoder": encoder_stats,
        "moe": moe_stats,
        "claim_encoder_segment_bytes_1_over_n": claim_enc,
        "claim_moe_routed_cost_bandit_visible": claim_moe,
    })
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale config for CI; claims still gate")
    args = ap.parse_args()
    payload = run(quick=args.quick, smoke=args.smoke)
    ok = all(payload[k] for k in payload if k.startswith("claim_"))
    for k in sorted(payload):
        if k.startswith("claim_"):
            print(f"{k}={payload[k]}")
    sys.exit(0 if ok else 1)
