"""Collate artifacts/dryrun/*.json into the §Dry-run / §Roofline tables."""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_all(suffix: str = "") -> dict:
    out = {}
    for f in glob.glob(os.path.join(ART, f"*{suffix}.json")):
        base = os.path.basename(f)[:-5]
        if suffix and not base.endswith(suffix.rstrip(".json")):
            continue
        if not suffix and ("_unroll" in base):
            continue
        d = json.load(open(f))
        if isinstance(d, list):
            d = d[0]
        out[base] = d
    return out


def run(quick: bool = False) -> dict:
    scanned = load_all()
    unrolled = load_all("_unroll")
    rows = []
    for key, d in sorted(unrolled.items()):
        if d.get("status") != "compiled":
            rows.append({"pair": key, "status": d.get("status")})
            continue
        rl = d["roofline"]
        rows.append({
            "arch": rl["arch"], "shape": rl["shape"],
            "t_compute_ms": rl["t_compute_s"] * 1e3,
            "t_memory_ms": rl["t_memory_s"] * 1e3,
            "t_collective_ms": rl["t_collective_s"] * 1e3,
            "dominant": rl["dominant"],
            "useful_flops_frac": rl["useful_flops_frac"],
            "temp_gb_per_chip": d["memory"]["temp_size_in_bytes"] / 1e9,
        })
    summary = {
        "n_compiled_scanned": sum(d.get("status") == "compiled"
                                  for d in scanned.values()),
        "n_total_scanned": len(scanned),
        "n_compiled_unrolled": sum(d.get("status") == "compiled"
                                   for d in unrolled.values()),
        "rows": rows,
    }
    return summary
