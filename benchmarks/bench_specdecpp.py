"""Table 4: training-based SpecDec++ classifier vs the training-free bandits
(Llama-1B/8B analog on SpecBench).  The classifier is trained on calibration
traces (alpaca-mix analog), following the paper's recipe: 4-layer residual
MLP + SiLU, BCE rejection weight 6, token-mixing 0.15, threshold 0.7."""
from __future__ import annotations

import numpy as np

from .common import (GAMMA_MAX, evaluate_method, get_corpus, run_method_suite,
                     save_json, trained_pair)
from repro.core import EngineSpec, StaticGamma, make_engine
from repro.core.controller import Controller
from repro.core.specdecpp import (collect_from_traces, make_specdecpp_arm,
                                  train_classifier)


class SpecDecPPController(Controller):
    name = "specdecpp"

    def __init__(self, arm, gamma_max: int):
        super().__init__([arm], gamma_max)

    def begin(self):
        return np.zeros((self.gamma_max,), np.int32)


def run(quick: bool = False) -> dict:
    draft, target = trained_pair("llama-1b-8b")
    corpus = get_corpus()

    # --- train the classifier on calibration traces (alpaca analog)
    traces = []
    eng = make_engine(draft, target, StaticGamma(gamma=8),
                      EngineSpec(backend="single", max_len=512))
    eng.collect_traces = True
    for _, ids in corpus.prompts("alpaca", 4 if quick else 10, seed=23):
        r = eng.generate(ids[:48], 48 if quick else 64)
        traces.extend(r.traces)
        eng.controller = StaticGamma(gamma=8)  # fresh lam per prompt
    X, y = collect_from_traces(traces)
    clf, losses = train_classifier(X, y, steps=300 if quick else 600)
    arm = make_specdecpp_arm(clf)

    prompts = [ids[:48] for _, ids in
               corpus.prompts("specbench", 13 if quick else 26, seed=29)]
    res = run_method_suite("llama-1b-8b", prompts,
                           methods=["static6", "tapout_seq_ts",
                                    "tapout_seq_ucb1", "tapout_token_ts",
                                    "tapout_token_ucb1"],
                           max_new=40 if quick else 64)
    sd = evaluate_method(draft, target, SpecDecPPController(arm, GAMMA_MAX),
                         prompts, max_new=40 if quick else 64)
    base = res["static6"]
    sd.speedup = base.cost_per_token / max(sd.cost_per_token, 1e-12)
    table = {k: {"m": v.m, "accept_rate": v.accept_rate, "speedup": v.speedup}
             for k, v in res.items()}
    table["specdecpp"] = {"m": sd.m, "accept_rate": sd.accept_rate,
                          "speedup": sd.speedup}
    out = {"table": table,
           "classifier_final_loss": losses[-1],
           "train_labels_reject_frac": float(np.mean(y)),
           "claim_sequcb1_beats_specdecpp":
               bool(table["tapout_seq_ucb1"]["speedup"] >=
                    table["specdecpp"]["speedup"])}
    save_json("table4_specdecpp", out)
    return out
