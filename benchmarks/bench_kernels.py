"""Kernel microbenchmarks: us/call for the XLA execution paths (CPU) and a
single interpret-mode Pallas validation call per kernel (TPU kernels cannot
be timed on CPU — the XLA path is what actually runs in CPU benches)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import sdpa
from repro.models.ssm import ssd_chunked
from .common import save_json


def _time(fn, *args, n=20, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run(quick: bool = False) -> dict:
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    rows = {}
    S = 512 if quick else 1024
    q = jax.random.normal(ks[0], (1, S, 8, 64))
    k = jax.random.normal(ks[1], (1, S, 2, 64))
    v = jax.random.normal(ks[2], (1, S, 2, 64))
    pos = jnp.arange(S, dtype=jnp.int32)
    f_naive = jax.jit(lambda *a: sdpa(*a, impl="naive"))
    f_flash = jax.jit(lambda *a: sdpa(*a, impl="flash_xla"))
    rows["sdpa_naive_us"] = _time(f_naive, q, k, v, pos, pos)
    rows["sdpa_flash_xla_us"] = _time(f_flash, q, k, v, pos, pos)

    x = jax.random.normal(ks[3], (1, S, 8, 64))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (1, S, 8)))
    A = -jnp.exp(jnp.arange(1, 9, dtype=jnp.float32) * 0.1)
    Bm = jax.random.normal(ks[3], (1, S, 1, 64))
    Cm = jax.random.normal(ks[4], (1, S, 1, 64))
    f_ssd = jax.jit(lambda *a: ssd_chunked(*a, 128)[0])
    rows["ssd_chunked_xla_us"] = _time(f_ssd, x, dt, A, Bm, Cm, n=5)

    # interpret-mode Pallas validation (correctness only, 1 call)
    from repro.kernels import ops, ref
    ops.FORCE_INTERPRET = True
    qq = jax.random.normal(ks[0], (1, 4, 128, 64))
    kk = jax.random.normal(ks[1], (1, 2, 128, 64))
    vv = jax.random.normal(ks[2], (1, 2, 128, 64))
    p = jnp.arange(128, dtype=jnp.int32)
    o = ops.flash_attention(qq, kk, vv, p, p, block_q=64, block_k=64)
    r = ref.flash_attention_ref(qq, kk, vv, p, p)
    rows["pallas_flash_max_err"] = float(np.abs(np.asarray(o) - np.asarray(r)).max())
    save_json("kernels_micro", rows)
    return rows
