"""Fig. 2: draft sqrt-entropy by draft position for ACCEPTED tokens,
coding vs non-coding prompts (motivates the online controller)."""
from __future__ import annotations

import numpy as np

from .common import GAMMA_MAX, get_corpus, save_json, trained_pair
from repro.core import EngineSpec, StaticGamma, make_engine


def run(quick: bool = False) -> dict:
    draft, target = trained_pair("llama-1b-8b")
    corpus = get_corpus()
    n = 3 if quick else 6
    buckets = {}
    for label, dataset in (("coding", "humaneval"), ("non-coding", "mt_bench")):
        per_pos = [[] for _ in range(GAMMA_MAX)]
        eng = make_engine(draft, target, StaticGamma(gamma=GAMMA_MAX),
                          EngineSpec(backend="single", max_len=512))
        eng.collect_traces = True
        for _, ids in corpus.prompts(dataset, n, seed=7):
            r = eng.generate(ids[:48], 48 if quick else 80)
            for tr in r.traces:
                for i in range(min(tr["n_accepted"], tr["n_drafted"])):
                    per_pos[i].append(float(tr["entropies"][i]))
        buckets[label] = [float(np.mean(v)) if v else None for v in per_pos]
    # claims: coding < non-coding at early positions; entropy decays with t
    c, nc = buckets["coding"], buckets["non-coding"]
    valid = [i for i in range(6) if c[i] is not None and nc[i] is not None]
    coding_lower = bool(np.mean([c[i] for i in valid]) <
                        np.mean([nc[i] for i in valid])) if valid else None
    first = [v for v in c[:3] if v is not None]
    last = [v for v in c[3:8] if v is not None]
    decays = bool(np.mean(last) <= np.mean(first) + 0.05) if first and last else None
    out = {"per_position_sqrt_entropy": buckets,
           "claim_coding_lower_entropy": coding_lower,
           "claim_entropy_decays": decays}
    save_json("fig2_entropy", out)
    return out
