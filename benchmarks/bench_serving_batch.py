"""Batched serving throughput: tokens/s and latency vs. concurrency B.

The headline claim of the continuous-batching scheduler: serving the SAME
request set at B=4 yields strictly higher measured tokens/s than draining
it sequentially at B=1 (the target model verifies 4 streams per forward,
amortizing per-tick dispatch overhead — the speculative-decoding bandwidth
argument, now across streams instead of within one).

Uses a random-init tiny pair (throughput only needs the hot path, not
acceptance quality) sized so a tick is DISPATCH-dominated — on a few-core
CPU host a large per-tick forward is compute-bound and batching cannot
amortize anything, which would measure the machine, not the scheduler.
One warmup drain per B keeps jit compilation out of the timed region, and
each B reports the best of ``repeats`` drains to damp scheduler noise.
``--smoke`` runs a seconds-scale config for CI and writes the JSON
artifact ``artifacts/bench/serving_batch_smoke.json``.
"""
from __future__ import annotations

import os
import sys
import time
from typing import List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _tiny_pair(n_layers_t=4, d_model_t=128, n_layers_d=2, d_model_d=64, V=61):
    import jax
    from repro.core import ModelBundle
    from repro.models import ModelConfig
    from repro.models import transformer as T
    tcfg = ModelConfig(name="srv_tgt", arch_type="dense",
                       num_layers=n_layers_t, d_model=d_model_t, num_heads=4,
                       num_kv_heads=2, d_ff=2 * d_model_t, vocab_size=V)
    dcfg = ModelConfig(name="srv_drf", arch_type="dense",
                       num_layers=n_layers_d, d_model=d_model_d, num_heads=2,
                       num_kv_heads=1, d_ff=2 * d_model_d, vocab_size=V)
    tp = T.init_params(tcfg, jax.random.PRNGKey(0))
    dp = T.init_params(dcfg, jax.random.PRNGKey(1))
    return ModelBundle(dp, dcfg), ModelBundle(tp, tcfg)


def _workload(n_requests: int, seed: int = 0) -> List[List[int]]:
    import numpy as np
    rng = np.random.default_rng(seed)
    # mixed prompt lengths exercise per-stream positions in the batch
    return [rng.integers(1, 60, size=int(rng.integers(4, 24))).tolist()
            for _ in range(n_requests)]


def _serve(draft, target, prompts, *, batch_size: int, max_new: int,
           gamma_max: int, max_len: int, seed: int = 0,
           repeats: int = 2) -> dict:
    from repro.core import make_controller
    from repro.serving.engine import SpecServer

    def drain(server, reqs):
        for p in reqs:
            server.submit(p, max_new)
        t0 = time.perf_counter()
        server.run_until_drained()
        return time.perf_counter() - t0

    # warmup drain: compiles the batched session program for this B plus
    # both prefill shapes (chunk + single; the long prompt covers the chunk)
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=gamma_max, seed=seed)
    srv = SpecServer(draft, target, ctrl, max_len=max_len,
                     max_concurrency=batch_size, seed=seed)
    warm = [list(range(1, 40))] + prompts[:min(batch_size, len(prompts)) - 1]
    drain(srv, warm)
    srv.responses.clear()

    best = None
    for _ in range(max(repeats, 1)):
        wall = drain(srv, prompts)
        stats = srv.throughput_stats()
        srv.responses.clear()
        stats["batch_size"] = batch_size
        stats["wall_s"] = wall
        stats["tokens_per_s"] = stats["total_new_tokens"] / max(wall, 1e-9)
        if best is None or stats["tokens_per_s"] > best["tokens_per_s"]:
            best = stats
    return best


def run(quick: bool = False, smoke: bool = False,
        batch_sizes: Optional[List[int]] = None) -> dict:
    from benchmarks.common import save_json

    if smoke:
        cfg = dict(n_requests=4, max_new=8, gamma_max=4, max_len=128)
        batch_sizes = batch_sizes or [1, 2]
        draft, target = _tiny_pair(n_layers_t=2, d_model_t=64,
                                   n_layers_d=1, d_model_d=32)
    elif quick:
        cfg = dict(n_requests=8, max_new=24, gamma_max=4, max_len=256)
        batch_sizes = batch_sizes or [1, 2, 4]
        draft, target = _tiny_pair(n_layers_t=2, d_model_t=64,
                                   n_layers_d=1, d_model_d=32)
    else:
        cfg = dict(n_requests=16, max_new=48, gamma_max=4, max_len=256)
        batch_sizes = batch_sizes or [1, 2, 4, 8]
        draft, target = _tiny_pair(n_layers_t=2, d_model_t=64,
                                   n_layers_d=1, d_model_d=32)

    prompts = _workload(cfg["n_requests"])
    rows = {}
    for B in batch_sizes:
        rows[B] = _serve(draft, target, prompts, batch_size=B,
                         max_new=cfg["max_new"], gamma_max=cfg["gamma_max"],
                         max_len=cfg["max_len"])
        print(f"  B={B}: {rows[B]['tokens_per_s']:.1f} tok/s  "
              f"p50={rows[B]['p50_latency_s']:.3f}s  "
              f"p95={rows[B]['p95_latency_s']:.3f}s", file=sys.stderr)

    base = rows[min(batch_sizes)]["tokens_per_s"]
    b_claim = 4 if 4 in rows else max(batch_sizes)
    payload = {
        "config": cfg,
        "batch_sizes": batch_sizes,
        "results": {str(b): rows[b] for b in batch_sizes},
        # headline: B=4 batched vs draining the same workload at B=1
        "claim_batched_beats_sequential":
            bool(rows[b_claim]["tokens_per_s"] > base),
        "speedup_vs_b1": {str(b): rows[b]["tokens_per_s"] / max(base, 1e-9)
                          for b in batch_sizes},
    }
    save_json("serving_batch_smoke" if smoke else "serving_batch", payload)
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI config")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    payload = run(quick=args.quick, smoke=args.smoke)
    ok = payload["claim_batched_beats_sequential"]
    print(f"claim_batched_beats_sequential={ok}")
    # --smoke is an artifact-producing CI exercise of the serving path; a
    # seconds-scale timing comparison on a noisy shared runner must not
    # gate the build.  Only full runs turn the claim into the exit code.
    sys.exit(0 if (ok or args.smoke) else 1)
