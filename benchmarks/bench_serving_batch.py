"""Batched serving throughput: tokens/s and latency vs. concurrency B,
plus the paged-vs-dense memory row.

The headline claim of the continuous-batching scheduler: serving the SAME
request set at B=4 yields strictly higher measured tokens/s than draining
it sequentially at B=1 (the target model verifies 4 streams per forward,
amortizing per-tick dispatch overhead — the speculative-decoding bandwidth
argument, now across streams instead of within one).

The PAGED row turns the block-pool memory win into a measured artifact:
the dense engine must allocate B x max_len KV rows whether requests use
them or not, so its concurrency is capped by worst-case memory; the paged
server is given the SAME token budget as the dense claim-B run
(pool_tokens = B_dense x max_len) but a wider slot pool, and the recorded
``peak_concurrency`` shows it running MORE short streams concurrently from
that budget (``claim_paged_admits_more``), alongside ``cache_pool_bytes``
and ``peak_blocks_in_use``.

The INT8-KV row (memory-constrained serving, docs/quantization.md) gives
the paged server TWICE the fp row's token budget stored quantized: the
pool must come in at no more bytes than the fp pool while sustaining at
least its concurrency (``claim_int8_kv_doubles_capacity_per_byte``).

The SHARDED rows (docs/sharding.md) serve the SAME workload on forced
host devices at increasing device counts — ``EngineSpec(mesh=
make_host_mesh(data=n))``, one subprocess per count because the XLA
device-count flag binds at jax init.  Tokens/s per count is recorded for
the trajectory (virtual CPU devices: informational, not a speedup
claim); the gating claim is that the bandit's ARM-SELECTION TRACE — every
per-session arm id, in request order — is device-count-invariant
(``claim_sharded_bandit_invariant``): TapOut's policy layer must not be
able to tell how many shards served the batch.

Uses a random-init tiny pair (throughput only needs the hot path, not
acceptance quality) sized so a tick is DISPATCH-dominated — on a few-core
CPU host a large per-tick forward is compute-bound and batching cannot
amortize anything, which would measure the machine, not the scheduler.
One warmup drain per B keeps jit compilation out of the timed region, and
each B reports the best of ``repeats`` drains to damp scheduler noise.
``--smoke`` runs a seconds-scale config for CI and writes the JSON
artifact ``artifacts/bench/serving_batch_smoke.json``.
"""
from __future__ import annotations

import os
import sys
import time
from typing import List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _tiny_pair(n_layers_t=4, d_model_t=128, n_layers_d=2, d_model_d=64, V=61):
    import jax
    from repro.core import ModelBundle
    from repro.models import ModelConfig
    from repro.models import transformer as T
    tcfg = ModelConfig(name="srv_tgt", arch_type="dense",
                       num_layers=n_layers_t, d_model=d_model_t, num_heads=4,
                       num_kv_heads=2, d_ff=2 * d_model_t, vocab_size=V)
    dcfg = ModelConfig(name="srv_drf", arch_type="dense",
                       num_layers=n_layers_d, d_model=d_model_d, num_heads=2,
                       num_kv_heads=1, d_ff=2 * d_model_d, vocab_size=V)
    tp = T.init_params(tcfg, jax.random.PRNGKey(0))
    dp = T.init_params(dcfg, jax.random.PRNGKey(1))
    return ModelBundle(dp, dcfg), ModelBundle(tp, tcfg)


def _workload(n_requests: int, seed: int = 0) -> List[List[int]]:
    import numpy as np
    rng = np.random.default_rng(seed)
    # mixed prompt lengths exercise per-stream positions in the batch
    return [rng.integers(1, 60, size=int(rng.integers(4, 24))).tolist()
            for _ in range(n_requests)]


def _dense_kv_bytes(server) -> int:
    """KV bytes the dense engine stacked for its B slots (both models)."""
    import jax
    from repro.models.cache import POOL_LEAF_KEYS
    total = 0
    def count(path, a):
        nonlocal total
        if getattr(path[-1], "key", None) in POOL_LEAF_KEYS:
            total += a.size * a.dtype.itemsize
        return a
    jax.tree_util.tree_map_with_path(count, server.engine.dcaches)
    jax.tree_util.tree_map_with_path(count, server.engine.tcaches)
    return total


def _serve(draft, target, prompts, *, batch_size: int, max_new: int,
           gamma_max: int, max_len: int, seed: int = 0,
           repeats: int = 2, paged: bool = False,
           pool_tokens: int = 0, block_size: int = 16,
           kv_dtype=None, fused: bool = True) -> dict:
    from repro.core import EngineSpec, make_controller
    from repro.serving.engine import SpecServer

    def drain(server, reqs):
        for p in reqs:
            server.submit(p, max_new)
        t0 = time.perf_counter()
        server.run_until_drained()
        return time.perf_counter() - t0

    # warmup drain: compiles the batched session program for this B plus
    # both prefill shapes (chunk + single; the long prompt covers the chunk)
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=gamma_max, seed=seed)
    spec = EngineSpec(backend="paged" if paged else "batched",
                      batch_size=batch_size, max_len=max_len, seed=seed,
                      kv_dtype=kv_dtype, fused=fused,
                      pool_tokens=pool_tokens if paged else None,
                      block_size=block_size)
    srv = SpecServer(draft, target, ctrl, spec=spec)
    warm = [list(range(1, 40))] + prompts[:min(batch_size, len(prompts)) - 1]
    drain(srv, warm)
    srv.responses.clear()
    srv.peak_concurrency = 0
    srv.backpressure_events = 0
    if paged:
        # warmup must not pollute the measured memory artifact either
        srv.engine.dalloc.peak_in_use = srv.engine.dalloc.blocks_in_use
        srv.engine.talloc.peak_in_use = srv.engine.talloc.blocks_in_use

    best = None
    for _ in range(max(repeats, 1)):
        wall = drain(srv, prompts)
        stats = srv.throughput_stats()
        srv.responses.clear()
        stats["wall_s"] = wall
        stats["tokens_per_s"] = stats["total_new_tokens"] / max(wall, 1e-9)
        if not paged:
            stats["cache_kv_bytes"] = _dense_kv_bytes(srv)
        # every row carries the settings that produced it (stable schema:
        # the engine's canonical describe() blob, hoisted for flat readers)
        eng = stats["engine"]
        stats.update(batch_size=eng["batch_size"], backend=eng["backend"],
                     devices=eng["devices"], kv_dtype=eng["kv_dtype"],
                     fused=eng["fused"])
        if best is None or stats["tokens_per_s"] > best["tokens_per_s"]:
            best = stats
    return best


# child script for the sharded rows: the forced-device-count flag binds at
# jax init, so every device count runs in a fresh interpreter.  The mesh is
# data-parallel (lanes sharded, bitwise numerics) so the arm trace must be
# EXACTLY the 1-device trace — see docs/sharding.md#numerics.
_SHARDED_CHILD = """
import json, sys, time
import jax
from benchmarks.bench_serving_batch import _tiny_pair, _workload
from repro.core import EngineSpec, make_controller
from repro.launch.mesh import make_host_mesh
from repro.serving.engine import SpecServer

cfg = json.loads(sys.argv[1])
draft, target = _tiny_pair(n_layers_t=2, d_model_t=64,
                           n_layers_d=1, d_model_d=32)
prompts = _workload(cfg["n_requests"])
mesh = make_host_mesh(data=cfg["devices"])
srv = SpecServer(draft, target,
                 make_controller("tapout_seq_ucb1",
                                 gamma_max=cfg["gamma_max"], seed=0),
                 spec=EngineSpec(backend="batched",
                                 batch_size=cfg["batch_size"],
                                 max_len=cfg["max_len"], mesh=mesh))

def drain(reqs):
    for p in reqs:
        srv.submit(p, cfg["max_new"])
    t0 = time.perf_counter()
    srv.run_until_drained()
    return time.perf_counter() - t0

drain([list(range(1, 40))] + prompts[:cfg["batch_size"] - 1])   # warmup
srv.responses.clear()
wall = drain(prompts)
resp = sorted(srv.responses, key=lambda r: r.request_id)
toks = sum(r.result.new_tokens for r in resp)
st = srv.engine.controller.bandit.state_dict()
eng = srv.engine.describe()
print("SHARDED_ROW " + json.dumps({
    "devices": len(jax.devices()),
    "mesh_axes": {k: int(v) for k, v in mesh.shape.items()},
    "wall_s": wall,
    "tokens_per_s": toks / max(wall, 1e-9),
    "total_new_tokens": toks,
    "engine": eng,
    "backend": eng["backend"],
    "batch_size": eng["batch_size"],
    "kv_dtype": eng["kv_dtype"],
    "arm_trace": [[s.arm for s in r.result.sessions] for r in resp],
    "bandit_counts": st["counts"].tolist(),
    "bandit_t": int(st["t"]),
}))
"""


def _sharded_rows(cfg: dict, batch_size: int, device_counts: List[int]):
    """One subprocess per device count; returns the parsed rows."""
    import json
    import subprocess
    from repro.launch.mesh import forced_host_env

    repo = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    rows = []
    for n in device_counts:
        env = forced_host_env(n)
        env["PYTHONPATH"] = os.pathsep.join(
            [repo, os.path.join(repo, "src")]
            + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p])
        payload = dict(cfg, devices=n, batch_size=batch_size)
        r = subprocess.run(
            [sys.executable, "-c", _SHARDED_CHILD, json.dumps(payload)],
            env=env, capture_output=True, text=True, timeout=1200)
        lines = [ln for ln in r.stdout.splitlines()
                 if ln.startswith("SHARDED_ROW ")]
        assert lines, (f"sharded child (devices={n}) produced no row:\n"
                       f"{r.stdout}\n{r.stderr}")
        rows.append(json.loads(lines[-1][len("SHARDED_ROW "):]))
    return rows


def run(quick: bool = False, smoke: bool = False,
        batch_sizes: Optional[List[int]] = None) -> dict:
    from benchmarks.common import record_serving_bench, save_json

    if smoke:
        cfg = dict(n_requests=4, max_new=8, gamma_max=4, max_len=128)
        batch_sizes = batch_sizes or [1, 2]
        draft, target = _tiny_pair(n_layers_t=2, d_model_t=64,
                                   n_layers_d=1, d_model_d=32)
    elif quick:
        cfg = dict(n_requests=8, max_new=24, gamma_max=4, max_len=256)
        batch_sizes = batch_sizes or [1, 2, 4]
        draft, target = _tiny_pair(n_layers_t=2, d_model_t=64,
                                   n_layers_d=1, d_model_d=32)
    else:
        cfg = dict(n_requests=16, max_new=48, gamma_max=4, max_len=256)
        batch_sizes = batch_sizes or [1, 2, 4, 8]
        draft, target = _tiny_pair(n_layers_t=2, d_model_t=64,
                                   n_layers_d=1, d_model_d=32)

    prompts = _workload(cfg["n_requests"])
    rows = {}
    for B in batch_sizes:
        rows[B] = _serve(draft, target, prompts, batch_size=B,
                         max_new=cfg["max_new"], gamma_max=cfg["gamma_max"],
                         max_len=cfg["max_len"])
        print(f"  B={B}: {rows[B]['tokens_per_s']:.1f} tok/s  "
              f"p50={rows[B]['p50_latency_s']:.3f}s  "
              f"p95={rows[B]['p95_latency_s']:.3f}s", file=sys.stderr)

    base = rows[min(batch_sizes)]["tokens_per_s"]
    b_claim = 4 if 4 in rows else max(batch_sizes)

    # ---- ragged regression gate: the length-aware ragged kernels + fused
    # single-dispatch tick exist precisely so that adding lanes cannot COST
    # throughput (padded-lane compute and per-tick host round-trips were
    # what made B=2 flap below B=1) — so tokens/s must be monotone
    # non-decreasing in B, with a small tolerance for timer noise on
    # shared CI runners.  Deterministic-ish (best-of-repeats), gates every
    # mode including --smoke.
    order = sorted(batch_sizes)
    speeds = [rows[b]["tokens_per_s"] for b in order]
    claim_monotone = bool(all(rows[b]["fused"] for b in order) and
                          all(b >= a * 0.95
                              for a, b in zip(speeds, speeds[1:])))
    trend = "  ".join("B=%d:%.1f" % (b, s) for b, s in zip(order, speeds))
    print(f"  claim_ragged_monotone_in_b={claim_monotone}  ({trend})",
          file=sys.stderr)

    # ---- paged row: SAME token budget as the dense claim-B run, wider slot
    # pool; short requests reserve only what they need, so the paged server
    # must sustain more concurrent streams than B_dense from those bytes
    b_paged = 2 * b_claim
    paged_prompts = _workload(max(cfg["n_requests"], 2 * b_paged), seed=1)
    paged = _serve(draft, target, paged_prompts, batch_size=b_paged,
                   max_new=cfg["max_new"], gamma_max=cfg["gamma_max"],
                   max_len=cfg["max_len"], paged=True,
                   pool_tokens=b_claim * cfg["max_len"], block_size=16)
    paged["max_concurrency"] = b_paged
    paged["dense_budget_B"] = b_claim
    paged["claim_paged_admits_more"] = bool(
        paged["peak_concurrency"] > b_claim)
    print(f"  paged B={b_paged} (budget of dense B={b_claim}): "
          f"{paged['tokens_per_s']:.1f} tok/s  "
          f"peak_concurrency={paged['peak_concurrency']}  "
          f"pool={paged['cache_pool_bytes']/1e6:.1f}MB  "
          f"peak_blocks={paged['peak_blocks_in_use']}", file=sys.stderr)

    # ---- memory-constrained row: the int8-KV server doubles the tokens of
    # the SAME byte budget (2x pool_tokens lands well under the fp pool's
    # bytes — int8 payload + f32 per-row scales vs fp32 pools), so a byte-
    # bound deployment admits at least as many concurrent streams
    quant = _serve(draft, target, paged_prompts, batch_size=b_paged,
                   max_new=cfg["max_new"], gamma_max=cfg["gamma_max"],
                   max_len=cfg["max_len"], paged=True,
                   pool_tokens=2 * b_claim * cfg["max_len"], block_size=16,
                   kv_dtype="int8")
    quant["pool_tokens_vs_fp"] = 2.0
    quant["claim_int8_kv_doubles_capacity_per_byte"] = bool(
        quant["cache_pool_bytes"] <= paged["cache_pool_bytes"]
        and quant["peak_concurrency"] >= paged["peak_concurrency"])
    print(f"  paged int8-KV B={b_paged} (2x tokens of the fp budget): "
          f"pool={quant['cache_pool_bytes']/1e6:.1f}MB vs "
          f"fp {paged['cache_pool_bytes']/1e6:.1f}MB  "
          f"peak_concurrency={quant['peak_concurrency']}", file=sys.stderr)

    # ---- sharded rows: same workload, increasing forced-host device
    # counts; tokens/s is trajectory data, the bandit-trace invariance is
    # the claim (data-parallel lanes -> the 1-device trace, exactly)
    dev_counts = [1, 2] if (smoke or quick) else [1, 2, 4]
    sharded = _sharded_rows(cfg, b_claim, dev_counts)
    traces = [r["arm_trace"] for r in sharded]
    counts = [r["bandit_counts"] for r in sharded]
    claim_sharded = bool(all(t == traces[0] for t in traces[1:])
                         and all(c == counts[0] for c in counts[1:]))
    for r in sharded:
        print(f"  sharded devices={r['devices']} "
              f"(mesh {r['mesh_axes']}): {r['tokens_per_s']:.1f} tok/s  "
              f"bandit_t={r['bandit_t']}", file=sys.stderr)
    print(f"  claim_sharded_bandit_invariant={claim_sharded}",
          file=sys.stderr)

    payload = {
        "config": cfg,
        "batch_sizes": batch_sizes,
        "results": {str(b): rows[b] for b in batch_sizes},
        # headline: B=4 batched vs draining the same workload at B=1
        "claim_batched_beats_sequential":
            bool(rows[b_claim]["tokens_per_s"] > base),
        "claim_ragged_monotone_in_b": claim_monotone,
        "speedup_vs_b1": {str(b): rows[b]["tokens_per_s"] / max(base, 1e-9)
                          for b in batch_sizes},
        "paged": paged,
        "claim_paged_admits_more": paged["claim_paged_admits_more"],
        "paged_int8_kv": quant,
        "claim_int8_kv_doubles_capacity_per_byte":
            quant["claim_int8_kv_doubles_capacity_per_byte"],
        "sharded": sharded,
        "claim_sharded_bandit_invariant": claim_sharded,
    }
    suffix = "_smoke" if smoke else ""
    save_json(f"serving_batch{suffix}", payload)
    save_json(f"serving_batch_paged{suffix}",
              {"config": cfg, "paged": paged,
               "dense_claim_row": rows[b_claim]})
    record_serving_bench(f"serving_batch{suffix}", {
        "tokens_per_s": {str(b): rows[b]["tokens_per_s"] for b in batch_sizes},
        "p95_latency_s": {str(b): rows[b]["p95_latency_s"]
                          for b in batch_sizes},
        "speedup_vs_b1": payload["speedup_vs_b1"],
        "engine": {str(b): rows[b]["engine"] for b in batch_sizes},
        "claim_batched_beats_sequential":
            payload["claim_batched_beats_sequential"],
        "claim_ragged_monotone_in_b": claim_monotone,
        "paged": {"tokens_per_s": paged["tokens_per_s"],
                  "peak_concurrency": paged["peak_concurrency"],
                  "cache_pool_bytes": paged["cache_pool_bytes"],
                  "claim_paged_admits_more": paged["claim_paged_admits_more"]},
        "paged_int8_kv": {
            "tokens_per_s": quant["tokens_per_s"],
            "peak_concurrency": quant["peak_concurrency"],
            "cache_pool_bytes": quant["cache_pool_bytes"],
            "claim_int8_kv_doubles_capacity_per_byte":
                quant["claim_int8_kv_doubles_capacity_per_byte"]},
        "sharded": {
            "tokens_per_s": {str(r["devices"]): r["tokens_per_s"]
                             for r in sharded},
            "bandit_t": {str(r["devices"]): r["bandit_t"] for r in sharded},
            "claim_sharded_bandit_invariant": claim_sharded},
    })
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI config")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    payload = run(quick=args.quick, smoke=args.smoke)
    ok = payload["claim_batched_beats_sequential"]
    ok_monotone = payload["claim_ragged_monotone_in_b"]
    ok_paged = payload["claim_paged_admits_more"]
    ok_sharded = payload["claim_sharded_bandit_invariant"]
    print(f"claim_batched_beats_sequential={ok}")
    print(f"claim_ragged_monotone_in_b={ok_monotone}")
    print(f"claim_paged_admits_more={ok_paged}")
    print(f"claim_sharded_bandit_invariant={ok_sharded}")
    # --smoke is an artifact-producing CI exercise of the serving path; a
    # seconds-scale TIMING comparison across DISTINCT workloads must not
    # gate the build there.  The monotone-in-B gate DOES gate every mode:
    # it compares the same workload against itself at growing B, which the
    # ragged+fused tick must never make slower (best-of-repeats + 5%
    # tolerance absorb runner noise).  The paged-admission and sharded-
    # bandit-invariance claims are deterministic and gate every mode.
    sys.exit(0 if ((ok or args.smoke) and ok_monotone and ok_paged
                   and ok_sharded) else 1)
