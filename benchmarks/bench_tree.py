"""Tree-vs-chain speculation: accepted-tokens-per-verify-pass + the
meta-bandit's online shape selection.

Two phases over the same synthetic workload and model pair:

1. **Forced shapes** — each speculation shape (chain + stop rule, or a
   static tree topology) runs alone (``FixedShape``), measuring the
   quantity the shapes compete on: accepted tokens per verify pass
   (``m`` averaged over sessions), plus acceptance rate, drafted nodes per
   session and the modeled cost per token.
2. **Meta-bandit** — one ``TapOutTreeSequence`` pool over the SAME shapes
   serves the workload; afterwards the bandit's pull counts / arm values
   must rank the empirically best shape first
   (``claim_bandit_tracks_best``), demonstrating that chain-vs-tree is a
   knob the TapOut meta-algorithm can own online.

Uses a CORRELATED tiny pair (draft = noise-perturbed target,
``_correlated_pair``): acceptance dynamics in the mid range are what the
shapes differentiate on — trees raise expected accepted-per-verify
exactly when the draft ranks the target's argmax in its top-k without
matching it at top-1.  ``--smoke`` runs a seconds-scale
config for CI and writes ``artifacts/bench/tree_spec_smoke.json``; every
run also appends its summary to the repo-root ``BENCH_serving.json``.
"""
from __future__ import annotations

import os
import sys
import time
from typing import List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _correlated_pair(sigma: float = 0.35, n_layers: int = 2,
                     d_model: int = 64, V: int = 61, cost_ratio: float = 0.15):
    """Draft = target with Gaussian weight noise (relative scale ``sigma``).

    Random INDEPENDENT tiny pairs agree ~1/V of the time — every shape
    then accepts ~0 and the bench measures nothing.  A perturbed copy
    gives the mid-range acceptance regime where speculation shapes
    actually differentiate (the draft often ranks the target's argmax in
    its top-k without matching it at top-1 — exactly when a tree beats a
    chain).  The modeled cost uses a nominal small-draft ratio, matching
    the repo's analog-pair convention (see ``common.trained_pair``)."""
    import jax
    from repro.core import ModelBundle
    from repro.models import ModelConfig
    from repro.models import transformer as T
    cfg = ModelConfig(name="tree_tgt", arch_type="dense",
                      num_layers=n_layers, d_model=d_model, num_heads=4,
                      num_kv_heads=2, d_ff=2 * d_model, vocab_size=V)
    tp = T.init_params(cfg, jax.random.PRNGKey(0))
    leaves, treedef = jax.tree.flatten(tp)
    keys = jax.random.split(jax.random.PRNGKey(42), len(leaves))
    noisy = [l + sigma * jax.numpy.std(l) * jax.random.normal(k, l.shape,
                                                              l.dtype)
             if l.ndim > 0 else l for l, k in zip(leaves, keys)]
    dp = jax.tree.unflatten(treedef, noisy)
    draft = ModelBundle(dp, cfg.replace(name="tree_drf"))
    target = ModelBundle(tp, cfg)
    target.cost_per_token = 1.0
    draft.cost_per_token = cost_ratio
    return draft, target


def _shapes(gamma_max: int, smoke: bool):
    from repro.core import chain_shape, tree_shape
    from repro.core import tree as trees
    from repro.core.arms import arm_by_name
    if smoke:
        return [chain_shape(arm_by_name("svip")),
                tree_shape(trees.wide(4, 2))]
    return [chain_shape(arm_by_name("max_confidence")),
            chain_shape(arm_by_name("adaedl")),
            tree_shape(trees.binary(3)),
            tree_shape(trees.wide(4, 4)),
            tree_shape(trees.from_branching((4, 2, 1)))]


def _workload(n_prompts: int, seed: int = 0) -> List[List[int]]:
    import numpy as np
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 60, size=int(rng.integers(4, 24))).tolist()
            for _ in range(n_prompts)]


def _run_engine(draft, target, controller, prompts, max_new, max_len, seed):
    from repro.core import EngineSpec, make_engine
    eng = make_engine(draft, target, controller,
                      EngineSpec(backend="tree", max_len=max_len, seed=seed))
    acc = drafted = sessions = new = 0
    cost = 0.0
    t0 = time.perf_counter()
    for p in prompts:
        r = eng.generate(p, max_new)
        acc += r.total_accepted
        drafted += r.total_drafted
        sessions += len(r.sessions)
        new += r.new_tokens
        cost += r.modeled_cost
    wall = time.perf_counter() - t0
    return {"accepted_per_verify": acc / max(sessions, 1),
            "accept_rate": acc / max(drafted, 1),
            "drafted_per_session": drafted / max(sessions, 1),
            "modeled_cost_per_token": cost / max(new, 1),
            "new_tokens": new, "sessions": sessions, "wall_s": wall,
            "tokens_per_s": new / max(wall, 1e-9)}


def run(quick: bool = False, smoke: bool = False) -> dict:
    import numpy as np

    from benchmarks.common import record_serving_bench, save_json
    from repro.core import FixedShape, TapOutTreeSequence

    if smoke:
        cfg = dict(n_prompts=2, max_new=12, bandit_prompts=4, gamma_max=6,
                   max_len=128, sigma=0.35)
    elif quick:
        cfg = dict(n_prompts=4, max_new=24, bandit_prompts=8, gamma_max=8,
                   max_len=256, sigma=0.35)
    else:
        cfg = dict(n_prompts=6, max_new=32, bandit_prompts=16, gamma_max=8,
                   max_len=256, sigma=0.35)
    draft, target = _correlated_pair(sigma=cfg["sigma"])

    shapes = _shapes(cfg["gamma_max"], smoke)
    prompts = _workload(cfg["n_prompts"])

    # ---- phase 1: forced per-shape measurement
    forced = {}
    for i, s in enumerate(shapes):
        forced[s.name] = _run_engine(
            draft, target, FixedShape(cfg["gamma_max"], s, seed=0), prompts,
            cfg["max_new"], cfg["max_len"], seed=0)
        print(f"  {s.name}: m/verify={forced[s.name]['accepted_per_verify']:.2f}"
              f"  drafted/sess={forced[s.name]['drafted_per_session']:.1f}"
              f"  cost/tok={forced[s.name]['modeled_cost_per_token']:.3g}",
              file=sys.stderr)
    best_name = max(forced, key=lambda n: forced[n]["accepted_per_verify"])

    # ---- phase 2: meta-bandit over the same shapes
    ctrl = TapOutTreeSequence(cfg["gamma_max"], "ucb1", "simple",
                              shapes=shapes, seed=0)
    bandit = _run_engine(draft, target, ctrl,
                         _workload(cfg["bandit_prompts"], seed=1),
                         cfg["max_new"], cfg["max_len"], seed=1)
    pulls = ctrl.shape_pulls
    values = np.asarray(ctrl.arm_values)
    names = [s.name for s in shapes]
    kinds = [s.kind for s in shapes]
    bandit_best = names[int(values.argmax())]
    # the demonstrable claim at this workload scale is KIND-level: arms of
    # the same kind can be near-tied (their gap is within bandit noise),
    # but the tree-vs-chain gap is large when one kind wins — the bandit's
    # preferred arm must be of the measured winner's kind, and the
    # within-kind regret is reported (not gated)
    best_m = forced[best_name]["accepted_per_verify"]
    best_kind = kinds[names.index(best_name)]
    claim = kinds[names.index(bandit_best)] == best_kind
    bandit_best_regret = 1.0 - forced[bandit_best]["accepted_per_verify"] \
        / max(best_m, 1e-9)
    # the pull mass must also shift toward the winning kind: mean pulls
    # per arm of the winner's kind exceed the other kind's (vacuously
    # true for a single-kind pool)
    kind_pulls = {k: [int(p) for p, kk in zip(pulls, kinds) if kk == k]
                  for k in set(kinds)}
    other = [k for k in kind_pulls if k != best_kind]
    claim_kind = all(
        np.mean(kind_pulls[best_kind]) > np.mean(kind_pulls[k])
        for k in other)
    print(f"  bandit: pulls={dict(zip(names, pulls.tolist()))}", file=sys.stderr)
    print(f"  measured best={best_name}  bandit best={bandit_best}",
          file=sys.stderr)

    payload = {
        "config": cfg,
        "shapes": names,
        "forced": forced,
        "bandit": {**bandit, "pulls": pulls.tolist(),
                   "arm_values": values.tolist(),
                   "best_shape": bandit_best},
        "measured_best_shape": best_name,
        "bandit_best_regret": float(bandit_best_regret),
        # the bandit's preferred arm is of the measured winner's KIND —
        # the meta-bandit owns the chain-vs-tree knob online
        "claim_bandit_tracks_best": bool(claim),
        "claim_shifts_to_winning_kind": bool(claim_kind),
        "claim_tree_in_pool_explored": bool(
            all(p > 0 for p in pulls.tolist())),
    }
    suffix = "_smoke" if smoke else ""
    save_json(f"tree_spec{suffix}", payload)
    record_serving_bench(f"tree_spec{suffix}", {
        "accepted_per_verify": {n: forced[n]["accepted_per_verify"]
                                for n in names},
        "modeled_cost_per_token": {n: forced[n]["modeled_cost_per_token"]
                                   for n in names},
        "measured_best_shape": best_name,
        "bandit_best_shape": bandit_best,
        "bandit_pulls": dict(zip(names, pulls.tolist())),
        "claim_bandit_tracks_best": bool(claim),
        "claim_shifts_to_winning_kind": bool(claim_kind),
    })
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI config")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    payload = run(quick=args.quick, smoke=args.smoke)
    ok = (payload["claim_bandit_tracks_best"]
          and payload["claim_shifts_to_winning_kind"])
    print(f"claim_bandit_tracks_best={payload['claim_bandit_tracks_best']}")
    print(f"claim_shifts_to_winning_kind="
          f"{payload['claim_shifts_to_winning_kind']}")
    print(f"claim_tree_in_pool_explored={payload['claim_tree_in_pool_explored']}")
    # smoke is an artifact-producing CI exercise: a 2-arm bandit over a
    # seconds-scale workload can legitimately still be exploring, so the
    # tracking claims gate only the full/quick runs
    sys.exit(0 if (ok or args.smoke) else 1)
