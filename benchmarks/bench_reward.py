"""Table 2 + Fig. 3: r_simple vs r_blend for sequence-level UCB1 on
SpecBench categories (blend should win on acceptance rate and speedup)."""
from __future__ import annotations

from collections import defaultdict

import numpy as np

from .common import (GAMMA_MAX, MethodResult, calibrated_pool,
                     evaluate_method, get_corpus, save_json, trained_pair)
from repro.core import SpecEngine, StaticGamma, TapOutSequence


def run(quick: bool = False) -> dict:
    draft, target = trained_pair("llama-1b-8b")
    corpus = get_corpus()
    per_cat = defaultdict(dict)
    prompts_by_cat = defaultdict(list)
    n = 13 if quick else 26
    for cat, ids in corpus.prompts("specbench", n, seed=11):
        prompts_by_cat[cat].append(ids[:48])
    spec_len = defaultdict(dict)
    for reward in ("simple", "blend"):
        for cat, prompts in sorted(prompts_by_cat.items()):
            ctrl = TapOutSequence(GAMMA_MAX, "ucb1", reward,
                                  pool=calibrated_pool("llama-1b-8b"))
            res = evaluate_method(draft, target, ctrl, prompts,
                                  max_new=40 if quick else 64)
            base = evaluate_method(draft, target, StaticGamma(6), prompts,
                                   max_new=40 if quick else 64)
            per_cat[cat][reward] = {
                "accept_rate": res.accept_rate, "m": res.m,
                "speedup": base.cost_per_token / max(res.cost_per_token, 1e-12)}
            # Fig 3: speculated length distribution
            hist = [h["n_drafted"] for h in ctrl.history]
            spec_len[cat][reward] = float(np.mean(hist)) if hist else 0.0

    cats = list(per_cat)
    wins_rate = sum(per_cat[c]["blend"]["accept_rate"] >=
                    per_cat[c]["simple"]["accept_rate"] for c in cats)
    wins_speed = sum(per_cat[c]["blend"]["speedup"] >=
                     per_cat[c]["simple"]["speedup"] for c in cats)
    simple_longer = sum(spec_len[c]["simple"] >= spec_len[c]["blend"]
                        for c in cats)

    # pooled run (primary claim): ONE online bandit across the whole
    # promptset — the paper's deployment setting; per-category numbers above
    # use 2 prompts each and are noise-dominated at this scale
    all_prompts = [p for c in sorted(prompts_by_cat) for p in prompts_by_cat[c]]
    pooled = {}
    pooled_len = {}
    base = evaluate_method(draft, target, StaticGamma(6), all_prompts,
                           max_new=40 if quick else 64)
    for reward in ("simple", "blend"):
        ctrl = TapOutSequence(GAMMA_MAX, "ucb1", reward,
                              pool=calibrated_pool("llama-1b-8b"))
        r = evaluate_method(draft, target, ctrl, all_prompts,
                            max_new=40 if quick else 64)
        pooled[reward] = {"accept_rate": r.accept_rate, "m": r.m,
                          "speedup": base.cost_per_token / max(r.cost_per_token, 1e-12)}
        pooled_len[reward] = float(np.mean(
            [h["n_drafted"] for h in ctrl.history]))
    out = {"per_category": dict(per_cat),
           "mean_speculated_length": dict(spec_len),
           "pooled": pooled, "pooled_speculated_length": pooled_len,
           "claim_blend_higher_accept_rate":
               bool(pooled["blend"]["accept_rate"] >= pooled["simple"]["accept_rate"]),
           "claim_blend_higher_speedup":
               bool(pooled["blend"]["speedup"] >= pooled["simple"]["speedup"]),
           "claim_simple_speculates_longer":
               bool(pooled_len["simple"] >= pooled_len["blend"]),
           "claim_blend_higher_accept_rate_frac": wins_rate / len(cats),
           "claim_blend_higher_speedup_frac": wins_speed / len(cats)}
    save_json("table2_reward", out)
    return out
